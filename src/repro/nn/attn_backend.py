"""Attention-backend registry and the ``PagedKV`` cache pytree.

Two things live here, both shared by every paged-attention
implementation so they cannot drift apart:

* **position primitives** — ``position_mask`` (the single source of
  truth for causal + sliding-window masking, used by dense decode, the
  blocked prefill path, the jnp paged gather AND the Pallas kernel) and
  ``repeat_kv`` (GQA group broadcast);
* **the backend registry** — paged decode attention now has two
  implementations (the jnp gather oracle and the Pallas page-walking
  kernel), selected by name.  ``resolve("auto")`` mirrors
  ``MappedModel.select_backend``: Pallas on TPU, the jnp oracle
  everywhere else (where the kernel still runs, in interpret mode, but
  only as a correctness vehicle, not a fast path).

A backend is a callable ``fn(q, kv, *, n_heads, head_dim, window) ->
[B, C, H, hd]`` that attends the already-projected queries over an
already-written :class:`PagedKV` (pools updated, view fields set).  The
scatter/write half of the step is *not* part of the backend contract —
it runs once in ``nn.attention.paged_decode_attention_block`` so the
returned pools are bitwise identical no matter which backend attends.

Every registered backend must match the jnp oracle **bit for bit** on
fp pools (asserted across page sizes / chunk widths / GQA ratios in
``tests/test_kernels.py``); serving leans on that to keep token streams
identical across ``--attn-impl`` settings.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0**30

# --------------------------------------------------------------------
# shared position primitives
# --------------------------------------------------------------------


def position_mask(q_pos: jax.Array, k_pos: jax.Array, window,
                  causal: bool) -> jax.Array:
    """Additive mask ``[..., qb, Sk]`` from absolute positions.

    ``window`` is a per-layer *scalar* (0 = full attention) so mixed
    local:global stacks stay scannable.  Masking on positions — never
    on page or ring geometry — is what makes every caller correct at
    page boundaries by construction: a chunk straddling two pages, or a
    ring cell that wrapped, is masked by where it *is* in the sequence,
    not where it lives in memory.
    """
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok = ok & (diff >= 0)
    ok = ok & ((window <= 0) | (diff < window))
    return jnp.where(ok, 0.0, NEG_INF)


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B,S,KV,hd] -> [B,S,H,hd] by group broadcast (TP-friendly heads)."""
    B, S, KV, hd = k.shape
    if KV == n_heads:
        return k
    reps = n_heads // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, reps, hd)).reshape(
        B, S, n_heads, hd)


# --------------------------------------------------------------------
# the PagedKV pytree
# --------------------------------------------------------------------


@dataclasses.dataclass
class PagedKV:
    """The paged KV cache as one typed pytree.

    Replaces the loose ``(k_pages, v_pages[, (k_scales, v_scales)])``
    tuples + four positional table/position arguments that previously
    threaded through every paged call site.  Two granularities share
    the type:

    * **pool-level** (what ``model.init_paged_kv`` returns and the
      donated serve state carries): ``k``/``v`` are
      ``[n_layers, N_pages, page, KV, hd]`` physical pools, int8 pools
      add f32 ``k_scale``/``v_scale`` planes ``[..., KV, 1]``; all view
      fields are ``None``.
    * **per-layer + per-call view** (what one attention call sees):
      pool leaves without the layer axis, plus ``block_tbl [B, n_ps]``
      (logical page -> physical page), ``pos [B, C]`` (absolute
      position per chunk slot), and the precomputed scatter coordinates
      ``page_ids``/``page_off [B, C]`` (out-of-range ids drop the
      write — how padded chunk slots are masked).

    ``None`` fields contribute no pytree leaves, so pool-level
    instances flow through ``jax.tree.map`` (page copy-on-write),
    ``lax.scan`` (per-layer slicing), buffer donation and
    ``NamedSharding`` trees exactly like the old tuples did.
    """

    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None
    block_tbl: Optional[jax.Array] = None
    pos: Optional[jax.Array] = None
    page_ids: Optional[jax.Array] = None
    page_off: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        """True for the int8 pool (scale planes present) — a *static*
        property: None-ness is pytree structure, not data, so it is
        knowable at trace time."""
        return self.k_scale is not None

    @property
    def n_pages(self) -> int:
        return self.k.shape[-4]

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]

    @property
    def nbytes(self) -> int:
        """Total bytes across all array leaves (pool accounting)."""
        return sum(int(x.nbytes) for x in jax.tree_util.tree_leaves(self))

    def with_view(self, block_tbl, pos, page_ids, page_off) -> "PagedKV":
        """Attach the per-call view (table + positions + scatter
        coordinates) to a pool, for one attention call."""
        return dataclasses.replace(self, block_tbl=block_tbl, pos=pos,
                                   page_ids=page_ids, page_off=page_off)

    def pool(self) -> "PagedKV":
        """Strip the per-call view, keeping only the pools — the form
        carried in serve state and stacked across layers by scan."""
        return dataclasses.replace(self, block_tbl=None, pos=None,
                                   page_ids=None, page_off=None)

    def scales(self) -> Optional[Tuple[jax.Array, jax.Array]]:
        """Legacy ``(k_scales, v_scales)`` tuple, or None (fp pool)."""
        if not self.quantized:
            return None
        return (self.k_scale, self.v_scale)


jax.tree_util.register_dataclass(
    PagedKV,
    data_fields=["k", "v", "k_scale", "v_scale", "block_tbl", "pos",
                 "page_ids", "page_off"],
    meta_fields=[],
)


# --------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------

_BACKENDS: Dict[str, Callable] = {}


def register(name: str, fn: Callable) -> None:
    """Register (or override) a paged-attention backend."""
    _BACKENDS[name] = fn


def get(name: str) -> Callable:
    if name not in _BACKENDS:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"registered: {available()}")
    return _BACKENDS[name]


def available() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def resolve(impl: str, platform: Optional[str] = None) -> str:
    """Resolve an ``attn_impl`` name to a registered backend.

    ``"auto"`` mirrors ``MappedModel.select_backend``: the Pallas
    kernel on TPU, the jnp oracle on every other platform.  Explicit
    names pass through (so ``--attn-impl pallas`` on CPU runs the
    kernel in interpret mode — slow, but the correctness leg CI uses).
    """
    if impl == "auto":
        platform = platform if platform is not None else jax.default_backend()
        return "pallas" if platform == "tpu" else "jnp"
    if impl not in _BACKENDS:
        raise ValueError(f"attn_impl must be 'auto' or one of "
                         f"{available()}; got {impl!r}")
    return impl


def valid_impls() -> Tuple[str, ...]:
    """Accepted ``attn_impl`` spellings (``"auto"`` + registered)."""
    return ("auto",) + available()


# --------------------------------------------------------------------
# the two in-tree backends
# --------------------------------------------------------------------


def _gathered_views(q: jax.Array, kv: PagedKV):
    """Logical [B, n_ps*page, KV, hd] K/V views through the block
    table, dequantized to ``q.dtype`` — the jnp oracle's gather, also
    the reference the kernel tests diff against."""
    dt = q.dtype
    B = q.shape[0]
    N_pages, page = kv.n_pages, kv.page_size
    n_ps = kv.block_tbl.shape[1]
    gtbl = jnp.clip(kv.block_tbl, 0, N_pages - 1)
    if kv.quantized:
        kf = (kv.k[gtbl].astype(dt) * kv.k_scale[gtbl].astype(dt)).reshape(
            B, n_ps * page, *kv.k.shape[2:])
        vf = (kv.v[gtbl].astype(dt) * kv.v_scale[gtbl].astype(dt)).reshape(
            B, n_ps * page, *kv.v.shape[2:])
    else:
        kf = kv.k[gtbl].reshape(B, n_ps * page, *kv.k.shape[2:])
        vf = kv.v[gtbl].reshape(B, n_ps * page, *kv.v.shape[2:])
    return kf.astype(dt), vf.astype(dt)


def _attend_jnp(q: jax.Array, kv: PagedKV, *, n_heads: int, head_dim: int,
                window) -> jax.Array:
    """The jnp oracle: gather the full logical view, mask on absolute
    positions, full-axis softmax.  Bitwise-reference semantics; every
    other backend is gated against this path."""
    B, C = q.shape[0], q.shape[1]
    S = kv.block_tbl.shape[1] * kv.page_size
    kf, vf = _gathered_views(q, kv)
    kf = repeat_kv(kf, n_heads)
    vf = repeat_kv(vf, n_heads)
    k_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mask = position_mask(kv.pos, k_pos, window, causal=True)  # [B, C, S]
    s = jnp.einsum("bqhd,bshd->bhqs", q, kf) / np.sqrt(head_dim)
    s = s.astype(jnp.float32) + mask[:, None, :, :]
    probs = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, vf)


def _attend_pallas(q: jax.Array, kv: PagedKV, *, n_heads: int,
                   head_dim: int, window) -> jax.Array:
    """The Pallas page-walking kernel (``kernels.paged_attention``).

    Imported lazily so this module stays importable without pulling the
    Pallas toolchain in (and so kernels can import the primitives above
    without a cycle).
    """
    from ..kernels.paged_attention import paged_attention
    return paged_attention(q, kv.k, kv.v, kv.block_tbl, kv.pos, window,
                           k_scale=kv.k_scale, v_scale=kv.v_scale)


register("jnp", _attend_jnp)
register("pallas", _attend_pallas)
