"""GQA/MQA attention with qk-norm, biases, sliding windows, KV caches.

One implementation serves every assigned arch:

* full/causal/local masks are arithmetic — the window is a per-layer
  *scalar*, so mixed local:global stacks (Gemma-3's 5:1) stay scannable
  with stacked params;
* GQA K/V are broadcast to full heads before the score einsum, so the
  head dimension shards cleanly over the 'model' mesh axis even when
  kv_heads < tensor-parallel degree (Megatron-style GQA TP);
* training/prefill use *blocked* attention (lax.scan over query blocks)
  so the S×S score matrix never materializes — the memory-roofline
  requirement for the 4k/32k shapes;
* decode is a functional cache update + single-row attention.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attn_backend as AB
from .attn_backend import NEG_INF, PagedKV
from .common import apply_rope, dense_init, rms_norm, split_keys

DEFAULT_Q_BLOCK = 512


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   qk_norm: bool = False) -> Dict:
    k = split_keys(key, 4)
    p = {
        "wq": dense_init(k[0], (d_model, n_heads * head_dim)),
        "wk": dense_init(k[1], (d_model, n_kv_heads * head_dim)),
        "wv": dense_init(k[2], (d_model, n_kv_heads * head_dim)),
        "wo": dense_init(k[3], (n_heads * head_dim, d_model)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((head_dim,), jnp.float32)
    return p


def _project_qkv(p: Dict, x: jax.Array, n_heads: int, n_kv_heads: int,
                 head_dim: int, positions: jax.Array, rope_theta: float,
                 qk_norm: bool, norm_eps: float):
    dt = x.dtype
    B, S, _ = x.shape
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


# shared position primitives live in attn_backend (the kernel and the
# dense/paged/blocked paths must mask identically); aliased here for
# the long-standing call sites and tests
_repeat_kv = AB.repeat_kv
_mask_block = AB.position_mask


def attend_blocked(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, k_pos: jax.Array, window,
                   *, causal: bool = True,
                   q_block: int = DEFAULT_Q_BLOCK) -> jax.Array:
    """Blocked softmax attention.  q [B,Sq,H,hd], k/v [B,Sk,H,hd].

    Scans over query blocks; the [B,H,qb,Sk] score tile is the peak
    intermediate (never Sq×Sk).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    qb = min(q_block, Sq)
    pad = (-Sq) % qb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
    nblk = (Sq + pad) // qb
    qt = q.reshape(B, nblk, qb, H, hd).transpose(1, 0, 2, 3, 4)
    pt = q_pos.reshape(B, nblk, qb).transpose(1, 0, 2)
    kT = k.transpose(0, 2, 3, 1)  # [B,H,hd,Sk]
    vT = v.transpose(0, 2, 1, 3)  # [B,H,Sk,hd]
    scale = 1.0 / np.sqrt(hd)

    def body(_, blk):
        qi, pi = blk  # [B,qb,H,hd], [B,qb]
        s = jnp.einsum("bqhd,bhds->bhqs", qi, kT) * scale
        m = _mask_block(pi, k_pos, window, causal)  # [B,qb,Sk]
        s = s.astype(jnp.float32) + m[:, None, :, :]
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhqs,bhsd->bqhd", p, vT)
        return None, o

    _, out = jax.lax.scan(body, None, (qt, pt))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nblk * qb, H, hd)
    return out[:, :Sq].reshape(B, Sq, H * hd)


def attention_block(
    p: Dict,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window,  # scalar per layer; 0 => global
    qk_norm: bool,
    norm_eps: float,
    positions: Optional[jax.Array] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    q_block: int = DEFAULT_Q_BLOCK,
    return_kv: bool = False,
):
    """Self (or cross, via kv_override [B,Sk,KV,hd]) attention, full seq.

    ``return_kv=True`` additionally returns the projected (k, v) so
    prefill can seed the decode cache without re-projection.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, positions,
                           rope_theta, qk_norm, norm_eps)
    if kv_override is not None:
        ko, vo = kv_override
        k_pos = jnp.broadcast_to(jnp.arange(ko.shape[1])[None],
                                 (B, ko.shape[1]))
        out = attend_blocked(q, _repeat_kv(ko, n_heads),
                             _repeat_kv(vo, n_heads), positions, k_pos,
                             jnp.int32(0), causal=False, q_block=q_block)
    else:
        out = attend_blocked(q, _repeat_kv(k, n_heads),
                             _repeat_kv(v, n_heads), positions, positions,
                             window, causal=causal, q_block=q_block)
    out = out @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def cross_kv(p: Dict, enc_out: jax.Array, n_kv_heads: int, head_dim: int):
    """Precompute encoder K/V for decoder cross-attention."""
    dt = enc_out.dtype
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, S, n_kv_heads, head_dim)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, S, n_kv_heads, head_dim)
    return k, v


def quantize_kv_int8(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[..., hd] bf16 -> (int8 values, per-vector scale [..., 1] f32).

    The serving-side analogue of the paper's action-bits quantization:
    stored intermediate results shrink to 8 bits, halving the dominant
    memory-roofline term of decode (EXPERIMENTS.md §Perf).
    """
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _paged_write(kv: PagedKV, k: jax.Array, v: jax.Array) -> PagedKV:
    """Scatter a chunk's projected K/V into their physical pages.

    Shared verbatim by every attention backend — the write half is not
    part of the backend contract, so the returned pools are bitwise
    identical no matter which implementation attends afterwards.
    Out-of-range ``page_ids`` drop the write (padded chunk slots).
    The int8 pool quantizes per token vector and scatters the f32
    scale planes alongside the values.
    """
    if kv.quantized:
        kq, ks = quantize_kv_int8(k)
        vq, vs = quantize_kv_int8(v)
        return dataclasses.replace(
            kv,
            k=kv.k.at[kv.page_ids, kv.page_off].set(kq, mode="drop"),
            v=kv.v.at[kv.page_ids, kv.page_off].set(vq, mode="drop"),
            k_scale=kv.k_scale.at[kv.page_ids, kv.page_off].set(
                ks.astype(kv.k_scale.dtype), mode="drop"),
            v_scale=kv.v_scale.at[kv.page_ids, kv.page_off].set(
                vs.astype(kv.v_scale.dtype), mode="drop"))
    return dataclasses.replace(
        kv,
        k=kv.k.at[kv.page_ids, kv.page_off].set(
            k.astype(kv.k.dtype), mode="drop"),
        v=kv.v.at[kv.page_ids, kv.page_off].set(
            v.astype(kv.v.dtype), mode="drop"))


def paged_decode_attention_block(
    p: Dict,
    x: jax.Array,  # [B, C, D] chunk of current tokens' activations
    kv: PagedKV,  # PagedKV with view fields set
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window,
    qk_norm: bool,
    norm_eps: float,
    impl: str = "jnp",
) -> Tuple[jax.Array, PagedKV]:
    """Chunked decode attention through a paged (block-table) KV cache.

    The serve-path analogue of ``decode_attention_block`` for the paged
    cache.  ``kv`` is a :class:`~repro.nn.attn_backend.PagedKV` with
    its per-call view attached (``kv.with_view(block_tbl, positions,
    page_ids, page_off)`` — the scatter coordinates are precomputed
    once per step by the caller and shared across layers).  The chunk's
    K/V are scattered into their physical pages (out-of-range ids drop
    the write, which is how padded chunk slots are masked), then every
    query attends over the *logical* view ``k_pages[block_tbl]`` —
    pages gathered in logical order, so cell ``i`` of the gathered axis
    holds absolute position ``i`` exactly like the dense cache holds
    position ``i`` before its ring wraps.  Masking uses the shared
    ``attn_backend.position_mask`` on the per-slot absolute positions,
    which makes it correct at page boundaries by construction: a chunk
    straddling two pages masks on positions, not on page geometry.
    Unwritten/stale cells (recycled pages) are killed by the causal
    term — a key cell is attended only when ``k_pos <= q_pos``, and
    every position ``<= q_pos`` of the owning slot has been written
    through its own table entry.

    ``impl`` selects the attention backend (``attn_backend.resolve``:
    ``'jnp'`` gather oracle, ``'pallas'`` page-walking kernel,
    ``'auto'`` = platform default).  The projection and the page write
    run *outside* the backend, so the returned pools are bitwise
    identical across impls, and registered backends are gated
    bit-identical on fp pools — token streams do not depend on the
    backend choice.

    Bit-exactness contract: for a chunk of width 1 starting at the same
    position, the gathered axis has the same length, values and mask as
    the (unwrapped) dense cache axis, so logits match the dense path
    bit for bit (asserted by tests/test_serve.py).

    A quantized ``kv`` (``k_scale``/``v_scale`` planes present) is the
    **int8 page pool**: K/V quantize per token vector
    (``quantize_kv_int8``) on write and the gather dequantizes before
    the score einsum, at the same ``<= scale/2`` round-trip bound as
    the dense int8 cache.  Shared (prefix) pages need nothing special:
    quantization is deterministic, so a shared page holds bit-identical
    content to what each sharer would have written itself.

    Returns ``(out, new_kv)`` — ``new_kv`` keeps the caller's view
    fields, so layer loops can thread it without rebuilding the view.
    """
    if not isinstance(kv, PagedKV):
        raise TypeError(
            "paged_decode_attention_block expects (p, x, PagedKV); the "
            "pre-PagedKV loose-args call shape was removed after its "
            "one-release deprecation window — wrap the pool in "
            "repro.nn.attn_backend.PagedKV and attach the view with "
            f".with_view(block_tbl, positions, page_ids, page_off) "
            f"(got kv={type(kv)})")
    B, C, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, kv.pos,
                           rope_theta, qk_norm, norm_eps)
    kv = _paged_write(kv, k, v)
    attend = AB.get(AB.resolve(impl))
    out = attend(q, kv, n_heads=n_heads, head_dim=head_dim, window=window)
    out = out.reshape(B, C, n_heads * head_dim) @ p["wo"].astype(x.dtype)
    return out, kv


def decode_attention_block(
    p: Dict,
    x: jax.Array,  # [B, 1, D] current token
    cache_k: jax.Array,  # [B, S_max, KV, hd]
    cache_v: jax.Array,
    pos: jax.Array,  # [] current position
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window,
    qk_norm: bool,
    norm_eps: float,
    gqa_impl: str = "repeat",  # 'repeat' (baseline) | 'grouped' (§Perf)
    kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # int8 cache
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[Tuple]]:
    """One decode step: insert K/V at ``pos % S_max`` (ring buffer for
    windowed layers sized to the window), attend over valid cells.

    ``gqa_impl='grouped'`` keeps the KV-head dimension grouped in the
    score einsums instead of broadcasting K/V to all query heads — the
    cache is read once, not ``H/KV`` times (the dominant decode memory
    term; see EXPERIMENTS.md §Perf iteration 1).
    ``kv_scales`` enables the int8 cache (iteration 2).
    """
    B = x.shape[0]
    S_max = cache_k.shape[1]
    int8_cache = cache_k.dtype == jnp.int8
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None],
                                 (B, 1))
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, positions,
                           rope_theta, qk_norm, norm_eps)
    slot = jnp.mod(pos, S_max)
    if int8_cache:
        sk, sv = kv_scales
        kq, ks = quantize_kv_int8(k)
        vq, vs = quantize_kv_int8(v)
        cache_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, slot, 0, 0))
        sk = jax.lax.dynamic_update_slice(
            sk, ks.astype(sk.dtype), (0, slot, 0, 0))
        sv = jax.lax.dynamic_update_slice(
            sv, vs.astype(sv.dtype), (0, slot, 0, 0))
        new_scales = (sk, sv)
        kf32 = cache_k.astype(x.dtype) * sk.astype(x.dtype)
        vf32 = cache_v.astype(x.dtype) * sv.astype(x.dtype)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
        new_scales = None
        kf32 = cache_k.astype(x.dtype)
        vf32 = cache_v.astype(x.dtype)
    # cell i holds absolute position: i if i <= slot else i + (filled wraps)
    idx = jnp.arange(S_max)
    wraps = (pos // S_max)
    abs_pos = jnp.where(idx <= slot, idx + wraps * S_max,
                        idx + (wraps - 1) * S_max)
    # once unwrapped to absolute positions, the ring shares the paged
    # path's mask helper (causal = abs_pos <= pos, window on the same
    # diff); the one ring-specific term is the abs_pos >= 0 guard —
    # pre-wrap cells sit at negative positions, which the causal diff
    # alone would wrongly admit
    mask = jnp.where(
        abs_pos[None] >= 0,
        AB.position_mask(jnp.asarray(pos, jnp.int32)[None, None],
                         abs_pos[None], window, causal=True)[:, 0],
        NEG_INF)  # [1,S]
    if gqa_impl == "grouped":
        KV = n_kv_heads
        G = n_heads // KV
        qg = q.reshape(B, 1, KV, G, head_dim)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kf32) / np.sqrt(head_dim)
        s = s.astype(jnp.float32) + mask[:, None, None, None, :]
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, vf32).reshape(
            B, 1, n_heads * head_dim)
    else:
        kf = _repeat_kv(kf32, n_heads)
        vf = _repeat_kv(vf32, n_heads)
        s = jnp.einsum("bqhd,bshd->bhqs", q, kf) / np.sqrt(head_dim)
        s = s.astype(jnp.float32) + mask[:, None, None, :]
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, vf).reshape(
            B, 1, n_heads * head_dim)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v, new_scales
