"""Gated MLP (SwiGLU / GeGLU) blocks."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import ACTIVATIONS, dense_init, split_keys


def init_mlp(key, d_model: int, d_ff: int) -> Dict:
    k = split_keys(key, 3)
    return {
        "w_gate": dense_init(k[0], (d_model, d_ff)),
        "w_up": dense_init(k[1], (d_model, d_ff)),
        "w_down": dense_init(k[2], (d_ff, d_model)),
    }


def mlp_block(p: Dict, x: jax.Array, act: str = "silu") -> jax.Array:
    dt = x.dtype
    fn = ACTIVATIONS[act]
    h = fn(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)
