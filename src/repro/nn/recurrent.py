"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM + sLSTM (xLSTM).

Training/prefill run the parallel forms (associative scan for RG-LRU,
stabilized quadratic form for mLSTM, lax.scan for sLSTM's true hidden
recurrence); decode carries O(1) state — which is why these archs run the
``long_500k`` shape that full-attention archs skip.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init, split_keys

# ------------------------------------------------------------------ RG-LRU
RGLRU_C = 8.0


def init_rglru(key, d_model: int, d_rnn: int, conv_width: int = 4) -> Dict:
    k = split_keys(key, 6)
    return {
        "w_lin": dense_init(k[0], (d_model, d_rnn)),
        "w_gate": dense_init(k[1], (d_model, d_rnn)),
        "w_out": dense_init(k[2], (d_rnn, d_model)),
        "w_rec_gate": dense_init(k[3], (d_rnn, d_rnn)),
        "w_in_gate": dense_init(k[4], (d_rnn, d_rnn)),
        "lam": jnp.linspace(0.9, 0.999, d_rnn).astype(jnp.float32),  # Λ
        "conv": dense_init(k[5], (conv_width, d_rnn)) * 0.1,
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along time.  x [B,S,R], w [W,R]."""
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # small static width
        out = out + pads[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out


def _rglru_coeffs(p: Dict, u: jax.Array):
    """u [B,S,R] (post-conv branch). Returns (a, b) of h_t = a h + b."""
    r = jax.nn.sigmoid(u @ p["w_rec_gate"].astype(u.dtype))
    i = jax.nn.sigmoid(u @ p["w_in_gate"].astype(u.dtype))
    log_a = (-RGLRU_C * jax.nn.softplus(-jnp.log(p["lam"] /
             (1 - p["lam"])))).astype(jnp.float32)  # base log a < 0
    log_a = log_a[None, None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-6)) * (
        i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, b


def rglru_block(p: Dict, x: jax.Array) -> jax.Array:
    """Griffin recurrent block over a full sequence."""
    dt = x.dtype
    u = x @ p["w_lin"].astype(dt)
    u = _causal_conv(u, p["conv"].astype(dt))
    a, b = _rglru_coeffs(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    return (h.astype(dt) * gate) @ p["w_out"].astype(dt)


def rglru_decode(p: Dict, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    """x [B,1,D]; state {h [B,R] f32, conv [B,W-1,R]}."""
    dt = x.dtype
    u_t = (x @ p["w_lin"].astype(dt))  # [B,1,R]
    W = p["conv"].shape[0]
    hist = jnp.concatenate([state["conv"], u_t.astype(jnp.float32)], axis=1)
    u_c = (hist * p["conv"].astype(jnp.float32)[None]).sum(axis=1,
                                                           keepdims=True)
    a, b = _rglru_coeffs(p, u_c.astype(dt))
    h = a[:, 0] * state["h"] + b[:, 0]
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    out = (h[:, None, :].astype(dt) * gate) @ p["w_out"].astype(dt)
    return out, {"h": h, "conv": hist[:, 1:]}


def rglru_init_state(batch: int, d_rnn: int, conv_width: int = 4) -> Dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), jnp.float32),
    }


# ------------------------------------------------------------------ mLSTM
def init_mlstm(key, d_model: int, n_heads: int) -> Dict:
    k = split_keys(key, 8)
    return {
        "wq": dense_init(k[0], (d_model, d_model)),
        "wk": dense_init(k[1], (d_model, d_model)),
        "wv": dense_init(k[2], (d_model, d_model)),
        "w_i": dense_init(k[3], (d_model, n_heads)) * 0.1,
        "w_f": dense_init(k[4], (d_model, n_heads)) * 0.1,
        "b_f": jnp.full((n_heads,), 3.0, jnp.float32),  # open forget gates
        "w_gate": dense_init(k[5], (d_model, d_model)),
        "w_out": dense_init(k[6], (d_model, d_model)),
        "conv": dense_init(k[7], (4, d_model)) * 0.1,
    }


MLSTM_CHUNK = 256


def mlstm_block(p: Dict, x: jax.Array, n_heads: int,
                chunk: int = MLSTM_CHUNK) -> jax.Array:
    """Chunkwise-parallel stabilized mLSTM (TPU adaptation).

    The paper-form parallel mLSTM materializes an S×S decay matrix; we
    instead scan over chunks of width ``chunk`` carrying the (C, n, m)
    recurrent state between chunks — intra-chunk quadratic (W×W in VMEM
    scale), inter-chunk linear.  Exactly equal to the recurrent form.
    """
    dt = x.dtype
    B, S, D = x.shape
    hd = D // n_heads
    W = min(chunk, S)
    pad = (-S) % W
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    u = _causal_conv(x, p["conv"].astype(dt)) + x
    q = (u @ p["wq"].astype(dt)).reshape(B, Sp, n_heads, hd)
    k = (u @ p["wk"].astype(dt)).reshape(B, Sp, n_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, Sp, n_heads, hd)
    log_i = (u @ p["w_i"].astype(dt)).astype(jnp.float32)  # [B,Sp,H]
    log_f = jax.nn.log_sigmoid(
        (u @ p["w_f"].astype(dt)).astype(jnp.float32) + p["b_f"][None, None])

    nc = Sp // W

    def to_chunks(t):  # [B,Sp,...] -> [nc,B,W,...]
        return t.reshape(B, nc, W, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)
    scale = 1.0 / np.sqrt(hd)
    intra_mask = jnp.tril(jnp.ones((W, W), bool))

    def body(carry, blk):
        C, n, m = carry  # [B,H,hd,hd], [B,H,hd], [B,H]
        qi, ki, vi, li, lf = blk
        L = jnp.cumsum(lf, axis=1)  # [B,W,H] within-chunk decay
        # per-query stabilizer: max(state decay, intra max)
        intra = L[:, :, None, :] - L[:, None, :, :] + li[:, None, :, :]
        intra = jnp.where(intra_mask[None, :, :, None], intra, -jnp.inf)
        intra_max = intra.max(axis=2)  # [B,W,H]
        state_decay = L + m[:, None, :]  # [B,W,H]
        m_q = jnp.maximum(state_decay, intra_max)
        a = jnp.exp(state_decay - m_q)  # state weight per query
        wgt = jnp.exp(intra - m_q[:, :, None, :])  # [B,W(i),W(j),H]
        qf = qi.astype(jnp.float32) * scale
        kf = ki.astype(jnp.float32)
        vf = vi.astype(jnp.float32)
        qk = jnp.einsum("bihd,bjhd->bijh", qf, kf)
        s = wgt * qk
        num = jnp.einsum("bijh,bjhd->bihd", s, vf) + \
            a[..., None] * jnp.einsum("bhkv,bihk->bihv", C, qf)
        den = s.sum(axis=2) + a * jnp.einsum("bhk,bihk->bih", n, qf)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_q))
        h = num / den[..., None]  # [B,W,H,hd]
        # state update to end of chunk
        Lw = L[:, -1]  # [B,H]
        m_new = jnp.maximum(m + Lw, (Lw[:, None] - L + li).max(axis=1))
        kw = jnp.exp(Lw[:, None] - L + li - m_new[:, None])  # [B,W,H]
        C_new = jnp.exp(m + Lw - m_new)[..., None, None] * C + \
            jnp.einsum("bjh,bjhk,bjhv->bhkv", kw, kf, vf)
        n_new = jnp.exp(m + Lw - m_new)[..., None] * n + \
            jnp.einsum("bjh,bjhk->bhk", kw, kf)
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, n_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, n_heads, hd), jnp.float32)
    m0 = jnp.full((B, n_heads), -1e30, jnp.float32)
    _, hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(0, 1).reshape(B, Sp, D)[:, :S].astype(dt)
    x = x[:, :S]
    gate = jax.nn.silu(x @ p["w_gate"].astype(dt))
    return (h * gate) @ p["w_out"].astype(dt)


def mlstm_init_state(batch: int, n_heads: int, hd: int) -> Dict:
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, n_heads * hd), jnp.float32),
    }


def mlstm_decode(p: Dict, x: jax.Array, state: Dict,
                 n_heads: int) -> Tuple[jax.Array, Dict]:
    dt = x.dtype
    B, _, D = x.shape
    hd = D // n_heads
    hist = jnp.concatenate(
        [state["conv"], x[:, 0, :].astype(jnp.float32)[:, None]], axis=1)
    u = (hist * p["conv"].astype(jnp.float32)[None]).sum(axis=1) + \
        x[:, 0].astype(jnp.float32)
    u = u.astype(dt)
    q = (u @ p["wq"].astype(dt)).reshape(B, n_heads, hd).astype(jnp.float32)
    k = (u @ p["wk"].astype(dt)).reshape(B, n_heads, hd).astype(jnp.float32)
    v = (x[:, 0] @ p["wv"].astype(dt)).reshape(B, n_heads, hd).astype(
        jnp.float32)
    log_i = (u @ p["w_i"].astype(dt)).astype(jnp.float32)  # [B,H]
    log_f = jax.nn.log_sigmoid(
        (u @ p["w_f"].astype(dt)).astype(jnp.float32) + p["b_f"][None])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    fprime = jnp.exp(log_f + state["m"] - m_new)
    iprime = jnp.exp(log_i - m_new)
    C = fprime[..., None, None] * state["C"] + \
        iprime[..., None, None] * k[..., :, None] * v[..., None, :]
    n = fprime[..., None] * state["n"] + iprime[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q / np.sqrt(hd))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q / np.sqrt(hd))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, D).astype(dt)
    gate = jax.nn.silu(x[:, 0] @ p["w_gate"].astype(dt))
    out = ((h * gate) @ p["w_out"].astype(dt))[:, None, :]
    return out, {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}


# ------------------------------------------------------------------ sLSTM
def init_slstm(key, d_model: int, n_heads: int) -> Dict:
    hd = d_model // n_heads
    k = split_keys(key, 3)
    # fused gate projections: input (4 gates) and recurrent (4 gates,
    # block-diagonal per head)
    return {
        "w_gates": dense_init(k[0], (d_model, 4 * d_model)),
        "r_gates": dense_init(k[1], (n_heads, hd, 4 * hd)) * 0.5,
        "b_gates": jnp.concatenate([
            jnp.zeros(d_model), jnp.full(d_model, 3.0),  # i, f biases
            jnp.zeros(2 * d_model)]).astype(jnp.float32),
        "w_out": dense_init(k[2], (d_model, d_model)),
    }


def slstm_block(p: Dict, x: jax.Array, n_heads: int) -> jax.Array:
    """True hidden-state recurrence -> sequential lax.scan over time."""
    dt = x.dtype
    B, S, D = x.shape
    hd = D // n_heads
    wx = (x @ p["w_gates"].astype(dt)).astype(jnp.float32)  # [B,S,4D]

    def step(carry, wx_t):
        c, n, m, h = carry  # all [B,H,hd] except m [B,H,hd]
        rec = jnp.einsum("bhd,hdk->bhk", h, p["r_gates"].astype(jnp.float32))
        z = wx_t.reshape(B, 4, n_heads, hd).transpose(0, 2, 1, 3)  # [B,H,4,hd]
        rec = rec.reshape(B, n_heads, 4, hd)
        b = p["b_gates"].reshape(4, n_heads, hd).transpose(1, 0, 2)
        g = z + rec + b[None]
        log_i = g[:, :, 0]
        log_f = jax.nn.log_sigmoid(g[:, :, 1])
        zin = jnp.tanh(g[:, :, 2])
        o = jax.nn.sigmoid(g[:, :, 3])
        m_new = jnp.maximum(log_f + m, log_i)
        ip = jnp.exp(log_i - m_new)
        fp = jnp.exp(log_f + m - m_new)
        c = fp * c + ip * zin
        n = fp * n + ip
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h), h

    zeros = jnp.zeros((B, n_heads, hd), jnp.float32)
    carry = (zeros, zeros, jnp.full((B, n_heads, hd), -1e30), zeros)
    _, hs = jax.lax.scan(step, carry, wx.transpose(1, 0, 2))  # [S,B,H,hd]
    hs = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(dt)
    return hs @ p["w_out"].astype(dt)


def slstm_init_state(batch: int, n_heads: int, hd: int) -> Dict:
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, n_heads, hd), -1e30), "h": z}


def slstm_decode(p: Dict, x: jax.Array, state: Dict,
                 n_heads: int) -> Tuple[jax.Array, Dict]:
    dt = x.dtype
    B, _, D = x.shape
    hd = D // n_heads
    wx = (x[:, 0] @ p["w_gates"].astype(dt)).astype(jnp.float32)
    rec = jnp.einsum("bhd,hdk->bhk", state["h"],
                     p["r_gates"].astype(jnp.float32)).reshape(B, n_heads, 4, hd)
    z = wx.reshape(B, 4, n_heads, hd).transpose(0, 2, 1, 3)
    b = p["b_gates"].reshape(4, n_heads, hd).transpose(1, 0, 2)
    g = z + rec + b[None]
    log_i, zin, o = g[:, :, 0], jnp.tanh(g[:, :, 2]), jax.nn.sigmoid(g[:, :, 3])
    log_f = jax.nn.log_sigmoid(g[:, :, 1])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    ip = jnp.exp(log_i - m_new)
    fp = jnp.exp(log_f + state["m"] - m_new)
    c = fp * state["c"] + ip * zin
    n = fp * state["n"] + ip
    h = o * c / jnp.maximum(n, 1.0)
    out = (h.reshape(B, D).astype(dt) @ p["w_out"].astype(dt))[:, None, :]
    return out, {"c": c, "n": n, "m": m_new, "h": h}
