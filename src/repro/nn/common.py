"""Shared NN primitives: norms, RoPE, initializers, dtype policy.

Parameters are plain dict pytrees.  Weights are stored f32 (master) and
cast to the compute dtype inside the forward pass (mixed precision).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_COMPUTE_DTYPE = jnp.bfloat16


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, hd]; positions [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., None, :]  # [..., S, 1, hd/2] broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis=0) -> jax.Array:
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)


def embed_init(key, shape) -> jax.Array:
    return jax.random.normal(key, shape, jnp.float32)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {"silu": jax.nn.silu, "gelu": gelu, "relu": jax.nn.relu}
