"""gemma3-27b [dense]: 62L d=5376 32H GQA(kv=16) d_ff=21504 vocab=262144.

5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3 family; unverified]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376, n_heads=32,
    n_kv_heads=16, d_ff=21504, vocab_size=262144, head_dim=128, qk_norm=True,
    local_window=1024, global_every=6, rope_theta=1e6, act="gelu",
    notes="global layers are full attention -> long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b-smoke", family="dense", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        qk_norm=True, local_window=8, global_every=6, act="gelu",
    )
