"""minitron-4b [dense]: 32L d=3072 24H GQA(kv=8) d_ff=9216 vocab=256000.

Pruned Nemotron.  [arXiv:2407.14679; hf]
24 heads don't divide the 16-way model axis: attention TP shards the
fused head*dim projection axis instead (DESIGN.md §4).
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=9216, vocab_size=256000, head_dim=128,
    notes="full attention -> long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="minitron-4b-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=3, n_kv_heads=1, d_ff=96, vocab_size=256, head_dim=16,
    )
