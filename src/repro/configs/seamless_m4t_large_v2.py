"""seamless-m4t-large-v2 [audio enc-dec]: 24L d=1024 16H d_ff=8192
vocab=256206 (padded to 256256 for even sharding).

Backbone only per spec: the speech frontend is a STUB — input_specs()
provides precomputed frame embeddings.  24 encoder + 24 decoder layers.
[arXiv:2308.11596; hf]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=256206,
    n_encoder_layers=24, frontend="audio", frontend_dim=160,
    frontend_seq=4096,
    notes="enc-dec; decode shapes lower the text decoder; long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke", family="encdec", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=256,
        n_encoder_layers=2, frontend="audio", frontend_dim=16,
        frontend_seq=16,
    )
