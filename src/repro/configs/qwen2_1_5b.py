"""qwen2-1.5b [dense]: 28L d=1536 12H GQA(kv=2) d_ff=8960 vocab=151936.

GQA + QKV bias.  [arXiv:2407.10671; hf]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, d_ff=8960, vocab_size=151936, head_dim=128, qkv_bias=True,
    rope_theta=1e6,
    notes="full attention -> long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-1.5b-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=3, n_kv_heads=1, d_ff=96, vocab_size=256, head_dim=16,
        qkv_bias=True,
    )
