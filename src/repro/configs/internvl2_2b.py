"""internvl2-2b [vlm]: 24L d=2048 16H GQA(kv=8) d_ff=8192 vocab=92553
(padded to 92672).  InternViT frontend is a STUB (precomputed patch
embeddings) + InternLM2 backbone.  [arXiv:2404.16821; hf]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553, head_dim=128,
    frontend="vit", frontend_dim=1024, frontend_seq=256,
    notes="full attention -> long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-smoke", family="vlm", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=256, head_dim=16,
        frontend="vit", frontend_dim=32, frontend_seq=8,
    )
