"""xlstm-125m [ssm]: 12L d=768 4H vocab=50304, sLSTM + mLSTM blocks.

d_ff=0: xLSTM blocks carry their own up-projection; no separate MLP.
Block pattern alternates mLSTM/sLSTM 1:1 (the 125M paper config mixes
both).  [arXiv:2405.04517; unverified]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    notes="recurrent -> long_500k RUNS",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m-smoke", family="ssm", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=256,
        block_pattern=("mlstm", "slstm"),
    )
