"""recurrentgemma-9b [hybrid]: 38L d=4096 16H MQA(kv=1) d_ff=12288
vocab=256000.  RG-LRU + local attention, pattern (rec, rec, attn).

[arXiv:2402.19427; unverified]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab_size=256000, head_dim=256,
    local_window=2048, block_pattern=("rglru", "rglru", "attn"),
    act="gelu",
    notes="RG-LRU + windowed attn -> long_500k RUNS (state is O(1))",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-smoke", family="hybrid", n_layers=3, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=256, head_dim=16,
        local_window=8, block_pattern=("rglru", "rglru", "attn"), act="gelu",
    )
