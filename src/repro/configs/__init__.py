"""Assigned-architecture configs (one module per arch) + registry."""
from .registry import ARCH_IDS, CLI_TO_MODULE, all_configs, get_config, get_smoke_config

__all__ = ["ARCH_IDS", "CLI_TO_MODULE", "all_configs", "get_config",
           "get_smoke_config"]
