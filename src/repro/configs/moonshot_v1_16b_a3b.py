"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H GQA(kv=16) expert_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).

[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=163840, head_dim=128,
    n_experts=64, n_experts_active=6,
    notes="full attention -> long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="moonshot-smoke", family="moe", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16,
        n_experts=8, n_experts_active=2,
    )
