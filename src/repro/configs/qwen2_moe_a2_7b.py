"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H GQA(kv=16) expert_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts.

60 experts pad to 64 for even 16-way expert parallelism; pad experts are
router-masked (DESIGN.md §4).  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from ..arch.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=151936, head_dim=128,
    n_experts=60, n_experts_active=4, n_shared_experts=4,
    shared_d_ff=4 * 1408,
    notes="full attention -> long_500k skipped",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-smoke", family="moe", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16,
        n_experts=6, n_experts_active=2, n_shared_experts=1, shared_d_ff=64,
    )
