"""Registry of assigned architectures (+ reduced smoke variants).

Each ``<arch>.py`` module defines ``CONFIG`` (exact published config) and
``smoke_config()`` (same family, tiny dims, CPU-runnable).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from ..arch.config import ArchConfig

ARCH_IDS: List[str] = [
    "qwen3_32b",
    "gemma3_27b",
    "minitron_4b",
    "qwen2_1_5b",
    "xlstm_125m",
    "seamless_m4t_large_v2",
    "recurrentgemma_9b",
    "moonshot_v1_16b_a3b",
    "qwen2_moe_a2_7b",
    "internvl2_2b",
]

# canonical CLI ids use dashes
CLI_TO_MODULE = {a.replace("_", "-"): a for a in ARCH_IDS}


def _module_name(arch: str) -> str:
    """Normalize any spelling (qwen2-1.5b, qwen2_1_5b, ...) to the module."""
    norm = arch.replace("-", "_").replace(".", "_")
    if norm in ARCH_IDS:
        return norm
    for a in ARCH_IDS:  # prefix match for convenience
        if a.startswith(norm):
            return a
    raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch)}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
